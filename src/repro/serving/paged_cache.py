"""Paged device KVCache with block tables (the vLLM-style substrate that
Mooncake's disaggregated pool feeds — §3 step 1 loads pool blocks into
these pages, step 2 stores new pages back).

Layout (per attention layer stacked on a leading axis):

    k_pages, v_pages : (L, n_pages, page_tokens, KV, Dh)
    block_table      : (B, max_pages_per_seq) int32 — page id per slot
    seq_lens         : (B,) int32

Page allocation is host-side (a free list); attention over pages is the
``paged_attention`` kernel (Pallas) or its jnp oracle. ``page_tokens`` is
the on-device granularity and the pool's 512-token block is a multiple of
it, so a pool block maps to an integer page run.

Two tiers of API live here:

* the original functional ``PagedKVCache`` helpers (``assign_seq`` /
  ``grow_seq`` / ``write_kv`` / ``gather_kv``) — a self-contained paged
  cache whose block table and pages move together;
* ``DevicePagePool`` — the engine's substrate: ONE page store shared by
  every worker in the process (the stand-in for a node's HBM), with
  per-page refcounts, a block-hash → page-run registry so slots that hit
  the same prefix chain share physical pages, copy-on-write for shared
  partial tail pages, and LRU eviction of registry-only runs under
  allocation pressure. ``PrefillWorker`` stages fresh KV into pages and
  ``DecodeWorker.join`` adopts the run into its block table — the
  zero-copy prefill→decode handoff.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.trace import BLOCK_TOKENS
from repro.models.layers import DTYPE


@dataclass
class PagedKVCache:
    k_pages: jax.Array          # (L, P, page, KV, Dh)
    v_pages: jax.Array
    block_table: jax.Array      # (B, max_pages) int32
    seq_lens: jax.Array         # (B,) int32
    page_tokens: int
    free: list = field(default_factory=list)   # host-side free page ids

    @property
    def n_layers(self) -> int:
        return self.k_pages.shape[0]

    @property
    def n_pages(self) -> int:
        return self.k_pages.shape[1]

    @property
    def max_pages_per_seq(self) -> int:
        return self.block_table.shape[1]


def init_paged_cache(cfg: ModelConfig, *, batch: int, n_pages: int,
                     page_tokens: int = 64,
                     max_seq: int = 32768) -> PagedKVCache:
    La = cfg.attention_layers
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    max_pages = (max_seq + page_tokens - 1) // page_tokens
    return PagedKVCache(
        k_pages=jnp.zeros((La, n_pages, page_tokens, KV, Dh), DTYPE),
        v_pages=jnp.zeros((La, n_pages, page_tokens, KV, Dh), DTYPE),
        block_table=jnp.zeros((batch, max_pages), jnp.int32),
        seq_lens=jnp.zeros((batch,), jnp.int32),
        page_tokens=page_tokens,
        free=list(range(n_pages - 1, 0, -1)),  # page 0 = null page
    )


# ---------------------------------------------------------------------------
# host-side allocation
# ---------------------------------------------------------------------------

def alloc_pages(cache: PagedKVCache, n: int) -> list[int]:
    if len(cache.free) < n:
        raise MemoryError(f"paged cache OOM: want {n}, free {len(cache.free)}")
    return [cache.free.pop() for _ in range(n)]


def free_seq(cache: PagedKVCache, slot: int) -> PagedKVCache:
    """Release all pages of a batch slot back to the free list."""
    table = np.asarray(cache.block_table)
    lens = np.asarray(cache.seq_lens)
    n_used = int(np.ceil(lens[slot] / cache.page_tokens))
    cache.free.extend(int(p) for p in table[slot, :n_used] if p != 0)
    table = table.copy()
    table[slot] = 0
    lens = lens.copy()
    lens[slot] = 0
    return PagedKVCache(cache.k_pages, cache.v_pages,
                        jnp.asarray(table), jnp.asarray(lens),
                        cache.page_tokens, cache.free)


def assign_seq(cache: PagedKVCache, slot: int, n_tokens: int) -> PagedKVCache:
    """Allocate pages for a new sequence of ``n_tokens`` in ``slot``."""
    n = (n_tokens + cache.page_tokens - 1) // cache.page_tokens
    pages = alloc_pages(cache, n)
    table = np.asarray(cache.block_table).copy()
    table[slot, :n] = pages
    table[slot, n:] = 0
    lens = np.asarray(cache.seq_lens).copy()
    lens[slot] = n_tokens
    return PagedKVCache(cache.k_pages, cache.v_pages,
                        jnp.asarray(table), jnp.asarray(lens),
                        cache.page_tokens, cache.free)


def grow_seq(cache: PagedKVCache, slot: int, extra: int = 1) -> PagedKVCache:
    """Extend a sequence; allocates a fresh page at a page boundary."""
    table = np.asarray(cache.block_table).copy()
    lens = np.asarray(cache.seq_lens).copy()
    old, new = int(lens[slot]), int(lens[slot]) + extra
    n_old = (old + cache.page_tokens - 1) // cache.page_tokens
    n_new = (new + cache.page_tokens - 1) // cache.page_tokens
    if n_new > n_old:
        pages = alloc_pages(cache, n_new - n_old)
        table[slot, n_old:n_new] = pages
    lens[slot] = new
    return PagedKVCache(cache.k_pages, cache.v_pages,
                        jnp.asarray(table), jnp.asarray(lens),
                        cache.page_tokens, cache.free)


# ---------------------------------------------------------------------------
# device-side reads / writes (jit-able; tables are traced inputs)
# ---------------------------------------------------------------------------

def write_kv(cache: PagedKVCache, slot: int, start: int,
             k_new: jax.Array, v_new: jax.Array) -> PagedKVCache:
    """Write (L, S, KV, Dh) new KV of one sequence into its pages,
    starting at token offset ``start``. Host loop over touched pages
    (S and the table are known host-side at engine level).

    A write that runs past the slot's assigned pages (page-table entry 0,
    the reserved null page) raises instead of silently corrupting page 0:
    callers must ``assign_seq``/``grow_seq`` first."""
    pt = cache.page_tokens
    table = np.asarray(cache.block_table)
    S = k_new.shape[1]
    k_pages, v_pages = cache.k_pages, cache.v_pages
    tok = start
    while tok < start + S:
        page_idx = tok // pt
        off = tok % pt
        n = min(pt - off, start + S - tok)   # stop at the page boundary
        if page_idx >= table.shape[1]:
            raise IndexError(
                f"write_kv overruns the block table: token {tok} needs page "
                f"index {page_idx} but the table holds {table.shape[1]} "
                f"pages per sequence (grow max_seq or shorten the write)")
        pid = int(table[slot, page_idx])
        if pid == 0:
            raise IndexError(
                f"write_kv into unassigned page: slot {slot} token {tok} "
                f"maps to table entry {page_idx} = 0 (the null page) — "
                f"assign_seq/grow_seq the sequence before writing")
        src = slice(tok - start, tok - start + n)
        k_pages = jax.lax.dynamic_update_slice(
            k_pages, k_new[:, src][:, None],
            (0, pid, off, 0, 0))
        v_pages = jax.lax.dynamic_update_slice(
            v_pages, v_new[:, src][:, None],
            (0, pid, off, 0, 0))
        tok += n
    return PagedKVCache(k_pages, v_pages, cache.block_table, cache.seq_lens,
                        pt, cache.free)


def gather_kv(cache: PagedKVCache, max_tokens: int):
    """Materialise per-sequence contiguous KV (L, B, max_tokens, KV, Dh)
    from pages via the block table — the pure-jnp paged read used by the
    engine on CPU (the Pallas kernel fuses this gather with attention).

    ``max_tokens`` that is not a multiple of ``page_tokens`` rounds UP to
    whole pages and the surplus tail tokens are sliced off — previously
    the partial page was silently dropped."""
    pt = cache.page_tokens
    n = (max_tokens + pt - 1) // pt
    tbl = cache.block_table[:, :n]                     # (B, n)
    k = cache.k_pages[:, tbl]                          # (L, B, n, pt, KV, Dh)
    v = cache.v_pages[:, tbl]
    L, B = k.shape[0], k.shape[1]
    k = k.reshape(L, B, n * pt, *k.shape[4:])[:, :, :max_tokens]
    v = v.reshape(L, B, n * pt, *v.shape[4:])[:, :, :max_tokens]
    return k, v


# ---------------------------------------------------------------------------
# shared device page pool (the engine's paged decode substrate)
# ---------------------------------------------------------------------------

class DevicePagePool:
    """One process-wide paged KV store: the stand-in for a serving node's
    HBM that both ``PrefillWorker`` (writes fresh pages, §3 step 2) and
    ``DecodeWorker`` (attends them through block tables, §3 step 4) share.

    * ``k_pages``/``v_pages``: (L, P, page_tokens, KV, Dh); page 0 is the
      reserved null page (block tables pad with it, reads of it are
      always masked).
    * per-page REFCOUNTS (host side): a page is held by the hash-run
      registry and/or by block-table rows / staged prefill results.
      ``release`` at refcount 0 returns it to the free list; below 0
      raises (double-free guard).
    * REGISTRY: pool block hash → integer page run (``BLOCK_TOKENS`` is a
      multiple of ``page_tokens``). Slots whose chains share a prefix
      adopt the SAME physical pages — the device-side analogue of the
      DRAM pool's prefix reuse. Registry-only runs (refcount 1) are
      evicted LRU under allocation pressure; runs referenced by a live
      slot are pinned.
    * COPY-ON-WRITE: ``make_writable`` copies a shared page before a slot
      appends into it; full prefix pages are never written during decode,
      so in practice COW only triggers at a shared partial tail page
      (e.g. one ``PrefillResult`` joined into several slots).
    * MESH SHARDING (``mesh=(data, model)``): the page slabs become ONE
      global array laid out under ``P(None, 'data', None, 'model', None)``
      — the page axis splits into per-data-shard BANKS of ``n_pages``
      pages each (so capacity scales ×data) and the KV-head axis stripes
      over the model axis (so per-device slab bytes shrink ÷model). Every
      host-side structure here stays LOGICAL and global: page ids are
      global (``bank_of`` recovers the bank, local id = global %
      ``bank_pages``, and global ids ≡ 0 mod ``bank_pages`` are each
      bank's reserved null page), refcounts/generations are one global
      array, and the registry/free lists are per bank because a data
      shard's rows can only attend pages resident on that shard. With
      ``mesh=None`` everything degrades to the original single-bank pool
      (``self.free``/``self.runs``/``self._lru`` ARE bank 0's objects).
    """

    def __init__(self, cfg: ModelConfig, *, n_pages: int,
                 page_tokens: int = 64, mesh=None) -> None:
        if BLOCK_TOKENS % page_tokens:
            raise ValueError(
                f"page_tokens={page_tokens} must divide the pool block "
                f"({BLOCK_TOKENS} tokens) so a block maps to a page run")
        La, KV, Dh = cfg.attention_layers, cfg.n_kv_heads, cfg.head_dim
        self.cfg = cfg
        self.page_tokens = page_tokens
        self.mesh = mesh
        d = 1
        if mesh is not None:
            d = int(mesh.shape.get("data", 1))
            m = int(mesh.shape.get("model", 1))
            if KV % m:
                raise ValueError(
                    f"{KV} kv heads do not stripe over model={m} shards")
        self.n_banks = d
        self.bank_pages = n_pages       # per-bank budget incl. its null page
        total = d * n_pages
        shape = (La, total, page_tokens, KV, Dh)
        if mesh is None:
            self._sharding = None
            self.k_pages = jnp.zeros(shape, DTYPE)
            self.v_pages = jnp.zeros(shape, DTYPE)
        else:
            from jax.sharding import NamedSharding, PartitionSpec
            self._sharding = NamedSharding(
                mesh, PartitionSpec(None, "data", None, "model", None))
            self.k_pages = jax.device_put(jnp.zeros(shape, DTYPE),
                                          self._sharding)
            self.v_pages = jax.device_put(jnp.zeros(shape, DTYPE),
                                          self._sharding)
        # reentrant: alloc -> eviction -> unregister -> release re-enters
        self._lock = threading.RLock()
        # one free list / registry / LRU per bank; bank 0's objects are
        # also exposed under the historical names so single-bank callers
        # (and every pre-mesh test) see the original pool unchanged
        self._bank_free: list[list[int]] = [           #: guarded_by self._lock
            list(range((b + 1) * n_pages - 1, b * n_pages, -1))
            for b in range(d)]
        self.free: list[int] = self._bank_free[0]  #: guarded_by self._lock
        self.refs = np.zeros(total, np.int32)  #: guarded_by self._lock
        self.gens = np.zeros(total, np.int64)  #: guarded_by self._lock
        self._bank_runs: list[dict[int, list[int]]] = [  #: guarded_by self._lock
            {} for _ in range(d)]
        self._bank_lru: list[list[int]] = [    #: guarded_by self._lock
            [] for _ in range(d)]
        self.runs: dict[int, list[int]] = self._bank_runs[0]  #: guarded_by self._lock
        self._lru: list[int] = self._bank_lru[0]   #: guarded_by self._lock
        #: guarded_by self._lock
        self.counters = dict(pages_written=0, shared_adoptions=0,
                             cow_copies=0, registry_evictions=0,
                             alloc_failures=0, pages_exported=0,
                             pages_imported=0)

    def _pin(self, x: jax.Array) -> jax.Array:
        """Keep a slab on its mesh sharding after an eager ``.at[]``
        update (eager updates preserve input shardings today; this guards
        the invariant rather than trusting it)."""
        if self._sharding is not None and x.sharding != self._sharding:
            x = jax.device_put(x, self._sharding)
        return x

    # ---- geometry ------------------------------------------------------
    @property
    def n_pages(self) -> int:
        """GLOBAL page count across every bank (``n_banks·bank_pages``
        — the historical meaning for an unmeshed single-bank pool)."""
        return self.k_pages.shape[1]

    def bank_of(self, page: int) -> int:
        """Data-shard bank a global page id lives on."""
        return page // self.bank_pages

    @property
    def pages_per_block(self) -> int:
        return BLOCK_TOKENS // self.page_tokens

    def pages_for(self, n_tokens: int) -> int:
        return (n_tokens + self.page_tokens - 1) // self.page_tokens

    @property
    def used_pages(self) -> int:
        with self._lock:
            return int((self.refs > 0).sum())

    @property
    def free_pages(self) -> int:
        """Mesh-wide LOGICAL free pages (sum over banks)."""
        with self._lock:
            return sum(len(f) for f in self._bank_free)

    @property
    def occupancy(self) -> float:
        """Fraction of usable pages (null pages excluded) currently held."""
        cap = self.n_pages - self.n_banks
        return self.used_pages / cap if cap else 1.0

    def pressure(self) -> dict:
        """Occupancy snapshot for admission backpressure — mesh-wide and
        LOGICAL: one page counted once no matter how its bytes stripe over
        the model axis, capacity summed over the data banks. ``pinned``
        pages (held by a live slot or staged result, not reclaimable) are
        the signal that matters: registry-only runs evict on demand, so
        high occupancy with low ``pinned_frac`` is a warm cache, not
        pressure."""
        with self._lock:
            cap = self.n_pages - self.n_banks
            evictable = sum(
                len(self._bank_runs[b][h])
                for b in range(self.n_banks)
                for h in self._evictable_locked(b))
            used = int((self.refs > 0).sum())
            pinned = used - evictable
            return dict(
                capacity=cap, free=sum(len(f) for f in self._bank_free),
                used=used, evictable=evictable, pinned=pinned,
                occupancy=used / cap if cap else 1.0,
                pinned_frac=pinned / cap if cap else 1.0)

    # ---- refcounted allocation ----------------------------------------
    def _evictable_locked(self, bank: int = 0) -> list[int]:
        """One bank's registered block hashes held ONLY by the registry,
        LRU first. Caller holds ``self._lock``."""
        return [h for h in self._bank_lru[bank]
                if all(self.refs[p] == 1 for p in self._bank_runs[bank][h])]

    def alloc(self, n: int, bank: int = 0) -> list[int]:
        """Take ``n`` fresh pages from one bank (refcount 1 each),
        evicting that bank's registry-only runs LRU when its free list
        runs short. Raises ``MemoryError`` (taking nothing) if pressure
        can't be relieved. Returned ids are GLOBAL."""
        with self._lock:
            free = self._bank_free[bank]
            if len(free) < n:
                for h in self._evictable_locked(bank):
                    self.unregister(h, bank=bank)
                    if len(free) >= n:
                        break
            if len(free) < n:
                self.counters["alloc_failures"] += 1
                raise MemoryError(
                    f"device page pool OOM: want {n} pages, "
                    f"free {len(free)} of {self.bank_pages - 1} "
                    f"in bank {bank}")
            pages = [free.pop() for _ in range(n)]
            for p in pages:
                self.refs[p] = 1
                self.gens[p] += 1
            return pages

    def best_stage_bank(self, hash_ids: list[int]) -> int:
        """Bank a fresh staging run should target: deepest registered
        prefix of this chain wins (maximises zero-copy adoption), free
        pages break ties (spreads load across the data shards)."""
        if self.n_banks == 1:
            return 0
        with self._lock:
            best, best_key = 0, None
            for b in range(self.n_banks):
                key = (self.lookup_chain(hash_ids, bank=b),
                       len(self._bank_free[b]), -b)
                if best_key is None or key > best_key:
                    best, best_key = b, key
            return best

    def gens_of(self, pages: list[int]) -> list[int]:
        """Allocation generations of a page run — a holder snapshots them
        and re-checks before taking late references (a freed-and-realloc'd
        page must read as STALE, never as someone else's KV)."""
        with self._lock:
            return [int(self.gens[p]) for p in pages]

    def retain(self, pages: list[int]) -> None:
        with self._lock:
            for p in pages:
                if self.refs[p] <= 0:
                    raise RuntimeError(f"retain of unowned page {p}")
                self.refs[p] += 1

    def release(self, pages: list[int]) -> None:
        with self._lock:
            for p in pages:
                if p == 0:
                    continue                # null-page padding in tables
                if self.refs[p] <= 0:
                    raise RuntimeError(f"double free of page {p}")
                self.refs[p] -= 1
                if self.refs[p] == 0:
                    self._bank_free[self.bank_of(p)].append(p)

    # ---- block-hash registry (cross-slot prefix sharing) ---------------
    def register_block(self, hash_id: int, pages: list[int]) -> None:
        """Publish a full block's page run for later chains to adopt.
        The registry holds one reference of its own. The run's bank is
        implied by its pages (a data shard's rows can only attend pages
        resident on that shard, so sharing never crosses banks — the same
        prefix may register independently per bank)."""
        assert len(pages) == self.pages_per_block
        bank = self.bank_of(pages[0])
        assert all(self.bank_of(p) == bank for p in pages), \
            f"page run straddles banks: {pages}"
        with self._lock:
            if hash_id in self._bank_runs[bank]:  # racing identical prefills
                return
            self.retain(pages)
            self._bank_runs[bank][hash_id] = list(pages)
            self._bank_lru[bank].append(hash_id)

    def unregister(self, hash_id: int, bank: Optional[int] = None) -> None:
        """Evict a registered run — from one bank, or (``bank=None``)
        from every bank holding an independent copy of it."""
        banks = range(self.n_banks) if bank is None else (bank,)
        with self._lock:
            for b in banks:
                pages = self._bank_runs[b].pop(hash_id, None)
                if pages is None:
                    continue
                self._bank_lru[b].remove(hash_id)
                self.release(pages)
                self.counters["registry_evictions"] += 1

    def lookup_chain(self, hash_ids: list[int], bank: int = 0) -> int:
        """Deepest consecutive registered prefix in one bank (no side
        effects)."""
        with self._lock:
            runs = self._bank_runs[bank]
            n = 0
            for h in hash_ids:
                if h not in runs:
                    break
                n += 1
            return n

    def adopt_chain(self, hash_ids: list[int],
                    bank: int = 0) -> tuple[int, list[int]]:
        """Retain + return the page runs of the chain's registered prefix
        in one bank: (n_blocks_adopted, flat page ids). The caller owns
        one reference per page; physical pages are SHARED with every
        other adopter of that bank."""
        with self._lock:
            n = self.lookup_chain(hash_ids, bank=bank)
            runs, lru = self._bank_runs[bank], self._bank_lru[bank]
            pages: list[int] = []
            for h in hash_ids[:n]:
                run = runs[h]
                self.retain(run)
                pages.extend(run)
                lru.remove(h)               # touch recency
                lru.append(h)
            if n:
                self.counters["shared_adoptions"] += n
            return n, pages

    # ---- device writes -------------------------------------------------
    def write_run(self, pages: list[int], k: np.ndarray,
                  v: np.ndarray) -> None:
        """Scatter (L, T, KV, Dh) KV into ``pages`` (T ≤ len(pages)·page).
        One fused indexed update per array; a partial tail page is
        zero-padded (fresh pages, nothing to preserve)."""
        pt = self.page_tokens
        L, T = k.shape[0], k.shape[1]
        n = len(pages)
        assert T <= n * pt, (T, n, pt)
        pad = n * pt - T
        k = jnp.asarray(k, self.k_pages.dtype)
        v = jnp.asarray(v, self.v_pages.dtype)
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        idx = jnp.asarray(pages, jnp.int32)
        shape = (L, n, pt) + k.shape[2:]
        with self._lock:
            self.k_pages = self._pin(
                self.k_pages.at[:, idx].set(k.reshape(shape)))
            self.v_pages = self._pin(
                self.v_pages.at[:, idx].set(v.reshape(shape)))
            self.counters["pages_written"] += n

    def make_writable(self, page: int) -> int:
        """Copy-on-write: return a page id safe to append into. A page
        with a single owner is returned as-is; a shared page is copied to
        a fresh page IN THE SAME BANK (a slot's pages must stay on its
        data shard; the caller must drop its reference to the old id and
        point its table at the new one)."""
        with self._lock:
            if self.refs[page] == 1:
                return page
            (new,) = self.alloc(1, bank=self.bank_of(page))
            self.k_pages = self._pin(
                self.k_pages.at[:, new].set(self.k_pages[:, page]))
            self.v_pages = self._pin(
                self.v_pages.at[:, new].set(self.v_pages[:, page]))
            self.release([page])
            self.counters["cow_copies"] += 1
            return new

    # ---- device↔host tier transfers (preemption spill/restore) ---------
    def export_run(self, pages: list[int], n_tokens: int):
        """Demote a live page run to host memory: gather its contiguous
        (L, n_tokens, KV, Dh) KV into fresh host arrays, then RELEASE the
        caller's reference to ``pages`` — ownership of the run transfers
        into the returned ``(k, v)`` bytes (the device→host rung of the
        HBM↔DRAM↔SSD ladder; ``import_run``/``stage_run`` bring them
        back). The arrays are explicit copies: freed pages may be
        reallocated and rewritten at any time, so no view of device
        buffers may escape."""
        k, v = self.read_seq(pages, n_tokens)
        # read_seq's np.asarray can alias the device buffer on CPU jax —
        # materialise before the pages go back on the free list
        k, v = k.copy(), v.copy()
        with self._lock:
            self.release(pages)
            self.counters["pages_exported"] += len(pages)
        return k, v

    def import_run(self, k: np.ndarray, v: np.ndarray,
                   n_tokens: int, bank: int = 0) -> list[int]:
        """Promote host KV back into device pages: alloc a fresh run in
        one bank and scatter ``(L, n_tokens, KV, Dh)`` into it. The
        caller owns one reference per returned page (the inverse of
        ``export_run``; the registry is NOT touched — use ``stage_run``
        to re-share full blocks). Raises ``MemoryError`` holding
        nothing."""
        pages = self.alloc(self.pages_for(n_tokens), bank=bank)
        try:
            self.write_run(pages, k[:, :n_tokens], v[:, :n_tokens])
        except BaseException:
            self.release(pages)
            raise
        with self._lock:
            self.counters["pages_imported"] += len(pages)
        return pages

    # ---- host-side reads (oracle/debug) --------------------------------
    def read_seq(self, pages: list[int], n_tokens: int):
        """Gather one sequence's contiguous (L, n_tokens, KV, Dh) KV."""
        idx = jnp.asarray(pages, jnp.int32)
        L = self.k_pages.shape[0]
        k = self.k_pages[:, idx]            # (L, n, pt, KV, Dh)
        v = self.v_pages[:, idx]
        k = k.reshape(L, -1, *k.shape[3:])[:, :n_tokens]
        v = v.reshape(L, -1, *v.shape[3:])[:, :n_tokens]
        return np.asarray(k), np.asarray(v)

    def stats(self) -> dict:
        """Unified snapshot (the cross-component ``stats()`` protocol:
        taken under the lock, plain dict, stable key names): lifetime
        counters + the ``pressure()`` occupancy gauges."""
        with self._lock:
            out = dict(self.counters)
            out.update(self.pressure())
            return out

    def check_leaks(self) -> None:
        """Invariant: every non-free page is referenced and vice versa,
        per bank; each bank's null page is never allocated (property
        tests call this after each op)."""
        with self._lock:
            n_free = 0
            free: set[int] = set()
            for b, bank_free in enumerate(self._bank_free):
                n_free += len(bank_free)
                free |= set(bank_free)
                assert all(self.bank_of(p) == b for p in bank_free), \
                    f"bank {b} free list holds foreign pages"
            for p in range(self.n_pages):
                if p % self.bank_pages == 0:    # a bank's null page
                    assert p not in free and self.refs[p] == 0, \
                        f"null page {p} entered circulation"
                elif p in free:
                    assert self.refs[p] == 0, \
                        f"freed page {p} still referenced"
                else:
                    assert self.refs[p] > 0, \
                        f"page {p} leaked (no ref, not free)"
            assert len(free) == n_free, "free list duplicates"
