"""Built-in decode-placement policies.

``min_tbt`` is the paper's SelectDecodingInstance: among instances with
VRAM headroom, the one whose predicted TBT after joining is lowest.

``kv_pressure`` additionally penalises placement by per-node KVCache
occupancy — and, crucially, its occupancy term ALWAYS counts pending
(accepted-but-still-prefilling) commitments, independent of the
``accounting`` knob. The knob reproduces the §7.2 time-lag ablation in
the TBT *estimate*; occupancy is about future VRAM pressure, where a
committed request consumes bytes whether or not it has started decoding.
Under naive ("current") accounting min_tbt piles concurrent arrivals
onto the momentarily-emptiest node; kv_pressure's lag-free pressure term
spreads them, so fewer later arrivals bounce off the ``vram_ok`` gate in
KV-heavy regimes. The returned TBT stays the honest ``predicted_tbt``
(SLO checks see latency, not the shaped score), mirroring the
Arm.score / Arm.ttft split.

``session_affinity`` pins multi-turn sessions to the node that served
their previous turn (identified by the deepest previously-placed block of
the request's hash chain), with bounded degradation: stickiness yields to
min_tbt once the home node's predicted TBT drifts past 1.5× the best
node's. See the class docstring for the memory/purity contract.

``include_pending`` is the Conductor's ``accounting`` knob (§7.2): the
naive baseline pre-selects on the CURRENT decode state only — accepted
requests still prefilling are invisible (the time lag that causes wasted
prefill) — while pending-aware accounting counts in-flight commitments.
"""
from __future__ import annotations

from collections import OrderedDict

from repro.core.policies.base import PolicyContext, register_policy


@register_policy("decode", "min_tbt")
class MinTBTDecode:
    def __init__(self, ctx: PolicyContext) -> None:
        self.ctx = ctx

    def select(self, req, instances, now, include_pending: bool = True):
        tokens = req.input_length + req.output_length
        ok = [d for d in instances if d.vram_ok(tokens, include_pending)]
        if not ok:
            return None, float("inf")
        d = min(ok, key=lambda d: d.predicted_tbt(
            1, tokens, include_pending=include_pending))
        return d, d.predicted_tbt(1, tokens, include_pending=include_pending)


@register_policy("decode", "kv_pressure")
class KVPressureDecode:
    """min_tbt shaped by per-node KV occupancy (see module docstring)."""

    alpha = 4.0     # quadratic penalty weight: mild until ~50% occupancy

    def __init__(self, ctx: PolicyContext) -> None:
        self.ctx = ctx

    def _occupancy(self, d, tokens: float) -> float:
        # pending commitments always count: bytes are promised to the node
        # regardless of the §7.2 accounting knob (see module docstring)
        held = d.kv_tokens + tokens + d.pending_tokens
        return held / max(d.cost.decode_capacity_tokens(), 1.0)

    def select(self, req, instances, now, include_pending: bool = True):
        tokens = req.input_length + req.output_length
        ok = [d for d in instances if d.vram_ok(tokens, include_pending)]
        if not ok:
            return None, float("inf")

        def score(d) -> float:
            tbt = d.predicted_tbt(1, tokens, include_pending=include_pending)
            occ = self._occupancy(d, tokens)
            return tbt * (1.0 + self.alpha * occ * occ) + 1e-9 * occ

        d = min(ok, key=score)
        return d, d.predicted_tbt(1, tokens, include_pending=include_pending)


@register_policy("decode", "session_affinity")
class SessionAffinityDecode:
    """Sticky decode placement for multi-turn sessions.

    A later turn of a chat/doc session extends the previous turn's hash
    chain, so the deepest previously-placed block on the chain identifies
    the node that last decoded this session. Returning there keeps the
    session's working set (decode-side KV, sampling state, any node-local
    caches a real deployment pins) on one machine instead of scattering a
    conversation across the pool.

    Stickiness is bounded: the home node is kept only while its predicted
    TBT stays within ``max_tbt_ratio`` of the best available node's (and
    it still has VRAM headroom) — a hot home degrades to plain min_tbt
    rather than dragging the session's SLO down with it.

    The placement map is policy-internal memory, recorded at selection
    time and bounded LRU (``max_tracked_blocks`` — idle sessions age out,
    so the map can't grow with total unique blocks seen over a long
    deployment); a post-selection SLO rejection can leave a mapping for a
    session that never joined, which at worst redirects its next turn
    through the bounded-degradation gate — never to an inadmissible node.
    The returned TBT stays the honest ``predicted_tbt`` of the pick (SLO
    checks see latency, not affinity), mirroring the Arm.score / Arm.ttft
    split.
    """

    max_tbt_ratio = 1.5        # sticky while home TBT <= ratio × best TBT
    max_tracked_blocks = 65536  # LRU bound on the placement map

    def __init__(self, ctx: PolicyContext) -> None:
        self.ctx = ctx
        # block key -> decode iid last chosen (LRU: recent sessions last)
        self._home: OrderedDict = OrderedDict()

    def select(self, req, instances, now, include_pending: bool = True):
        tokens = req.input_length + req.output_length
        ok = [d for d in instances if d.vram_ok(tokens, include_pending)]
        if not ok:
            return None, float("inf")

        def tbt(d) -> float:
            return d.predicted_tbt(1, tokens, include_pending=include_pending)

        best = min(ok, key=tbt)
        pick = best
        home_iid = next((self._home[h] for h in reversed(req.hash_ids)
                         if h in self._home), None)
        if home_iid is not None:
            home = next((d for d in ok if d.iid == home_iid), None)
            if home is not None and tbt(home) <= self.max_tbt_ratio * tbt(best):
                pick = home
        for h in req.hash_ids:
            self._home[h] = pick.iid
            self._home.move_to_end(h)
        while len(self._home) > self.max_tracked_blocks:
            self._home.popitem(last=False)
        return pick, tbt(pick)
