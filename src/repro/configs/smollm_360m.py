"""SmolLM-360M (llama-arch small). [hf:HuggingFaceTB/SmolLM-135M family]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="smollm-360m",
    kind="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    rope_theta=1e4,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M (assignment: 32L d960 15H kv5)",
))
