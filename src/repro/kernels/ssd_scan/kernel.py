"""Chunked SSD (state-space duality) scan — Pallas TPU kernel.

Mamba2's mixer (arXiv:2405.21060) for the SSM / hybrid architectures.
The SSD form splits the sequence into chunks: within a chunk the output is
a masked "attention" (C Bᵀ ∘ L) — dense matmuls that feed the MXU — and
across chunks a tiny (P × N) recurrent state carries over, so the scan is
sequential only at chunk granularity.

Grid (batch, heads, chunks): chunks is the innermost, sequential
dimension; the running state h (P × N fp32) lives in VMEM scratch and
persists across the chunk steps of one (b, h) pair. Per-step working set:
x (l×P), B/C (l×N), the l×l decay mask, and h — ≈ 600 KiB at l = 256,
P = 64, N = 128; MXU-aligned contractions throughout.

B/C are single-group (shared across heads, the Mamba2 default), so their
BlockSpec ignores the head index — no per-head replication in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, h0_ref,
                y_ref, hT_ref, h_scr, *, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0]                    # (P, N) fp32

    x = x_ref[0, 0].astype(jnp.float32)              # (l, P)
    dt = dt_ref[0, 0].astype(jnp.float32)            # (1, l)  (see ops)
    A = A_ref[0]                                     # scalar decay rate
    Bm = B_ref[0].astype(jnp.float32)                # (l, N)
    Cm = C_ref[0].astype(jnp.float32)                # (l, N)
    l = x.shape[0]

    xdt = x * dt[0][:, None]                         # (l, P)
    dA = dt[0] * A                                   # (l,)
    cum = jnp.cumsum(dA)                             # (l,)

    # intra-chunk: Y_diag = ((C Bᵀ) ∘ L) (x·dt), L = exp(segsum(dA)) lower-tri
    seg = cum[:, None] - cum[None, :]                # (l, l)
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    Lmask = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * Lmask, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the incoming state, decayed per position
    h_prev = h_scr[...]                              # (P, N)
    state_decay = jnp.exp(cum)                       # (l,)
    y = y + jax.lax.dot_general(
        Cm * state_decay[:, None], h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: h = h_prev * exp(cum[-1]) + (x·dt)ᵀ (B · decay_to_end)
    decay_states = jnp.exp(cum[-1] - cum)            # (l,)
    chunk_state = jax.lax.dot_general(
        xdt, Bm * decay_states[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (P, N)
    h_scr[...] = h_prev * jnp.exp(cum[-1]) + chunk_state

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        hT_ref[0, 0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, h0=None, *, chunk: int = 256,
             interpret: bool = False):
    """x: (b, s, h, p); dt: (b, s, h); A: (h,); B, C: (b, s, n) single-group.
    s % chunk == 0. Returns (y (b, s, h, p) fp32, state (b, h, p, n) fp32)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    c = s // chunk
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    # layouts the kernel wants: head-major sequence blocks
    x_t = x.transpose(0, 2, 1, 3)                    # (b, h, s, p)
    dt_t = dt.transpose(0, 2, 1)[:, :, None, :]      # (b, h, 1, s)

    kernel = functools.partial(_ssd_kernel, n_chunks=c)
    y, hT = pl.pallas_call(
        kernel,
        grid=(b, h, c),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda ib, ih, ic: (ib, ih, 0, ic)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_tpu_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_t, dt_t, A.astype(jnp.float32), B, C, h0)
    return y.transpose(0, 2, 1, 3), hT


def _tpu_params(dimension_semantics):
    try:
        return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
    except (AttributeError, TypeError):
        return dict(dimension_semantics=dimension_semantics)
