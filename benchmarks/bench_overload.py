"""Table 3 + Figures 9/10: overload-oriented scheduling — rejected-request
counts and load-fluctuation traces for baseline / early / predictive
admission (8P+8D cluster, 2× replay of the trace, §8.2)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.simulator import MooncakeCluster
from repro.core.trace import TraceSpec, generate_trace


def main(fast: bool = False):
    cfg = get_config("llama2-70b")
    n = 3000 if fast else 23_000
    # decode-binding overload (out_mu up → long decodes, §7's regime)
    reqs = generate_trace(TraceSpec(n_requests=n, seed=2, out_mu=5.9))
    rows = []
    fluct = []
    for adm in ("baseline", "early", "predictive"):
        mc = MooncakeCluster(cfg, n_prefill=8, n_decode=8, ttft_slo=30,
                             tbt_slo=0.1, admission=adm, t_d=20.0)
        res = mc.run(reqs, speedup=4.0, load_sample_dt=5.0)
        waste = sum(1 for r in res.records
                    if r.reject_stage == "decode_doublecheck")
        wasted_prefill_s = sum(
            max(r.ttft, 0.0) for r in res.records
            if r.reject_stage == "decode_doublecheck")
        loads = np.array([(p, d) for _, p, d in res.load_samples])
        rows.append(dict(
            policy=adm,
            rejected=len(res.rejected()),
            rejected_after_prefill=waste,
            wasted_prefill_s=round(wasted_prefill_s, 1),
            completed=len(res.completed()),
            goodput_rps=round(res.goodput(30, 0.1), 3),
            decode_load_std=round(float(loads[:, 1].std()), 3),
            prefill_decode_corr=round(float(
                np.corrcoef(loads[:, 0], loads[:, 1])[0, 1]), 3),
        ))
        for t, p, d in res.load_samples[:: max(len(res.load_samples) // 40,
                                               1)]:
            fluct.append(dict(policy=adm, t=round(t, 1),
                              prefill_load=round(p, 3),
                              decode_load=round(d, 3)))
    emit("table3_overload_policies", rows)
    emit("fig9_10_load_fluctuation", fluct)
    return rows


if __name__ == "__main__":
    main()
