"""Mamba2-2.7B — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    kind="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64),
    tie_embeddings=True,
    source="arXiv:2405.21060 (assignment: 64L d2560 attn-free state=128)",
))
