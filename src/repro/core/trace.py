"""Request traces — the Mooncake open-trace format (§4) plus a generator
that reproduces the paper's workload statistics.

Open format (JSONL), one request per line::

    {"timestamp": 27482, "input_length": 6955, "output_length": 52,
     "hash_ids": [46, 47, ..., 2354]}

* ``timestamp``      — relative arrival time in milliseconds (0 .. 3,600,000)
* ``input_length``   — number of input tokens
* ``output_length``  — number of output tokens
* ``hash_ids``       — prefix-chained block hashes (block = 512 tokens);
                       identical ids ⇒ identical token block *and* prefix,
                       hence KVCache-reusable (Figure 3).

The generator targets the paper's §4.2 statistics:
  avg input ≈ 7,590 tokens; avg output ≈ 182 tokens; ~23.6k requests/hour;
  >50% of blocks never reused while hot blocks (system prompts) are hit
  tens of thousands of times (Figure 6); max theoretical reuse ≈ 50%
  (Table 1 ∞-capacity hit rate ≈ 0.51).
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Iterable, Iterator, Optional

import numpy as np

BLOCK_TOKENS = 512  # the paper's trace block size


@dataclass
class Request:
    req_id: int
    timestamp: int          # ms
    input_length: int       # tokens
    output_length: int      # tokens
    hash_ids: list[int]     # prefix-chained block ids, len == ceil(in/512)
    priority: int = 0       # 0 = normal; higher = more important (§10)

    @property
    def n_blocks(self) -> int:
        return len(self.hash_ids)

    def to_json(self) -> str:
        return json.dumps(dict(timestamp=self.timestamp,
                               input_length=self.input_length,
                               output_length=self.output_length,
                               hash_ids=self.hash_ids))


def load_trace(path: str, limit: Optional[int] = None) -> list[Request]:
    """Load the Mooncake open JSONL trace format verbatim."""
    out: list[Request] = []
    with open(path) as f:
        for i, line in enumerate(f):
            if limit is not None and i >= limit:
                break
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            out.append(Request(req_id=i, timestamp=int(d["timestamp"]),
                               input_length=int(d["input_length"]),
                               output_length=int(d["output_length"]),
                               hash_ids=list(d["hash_ids"])))
    out.sort(key=lambda r: r.timestamp)
    return out


def save_trace(requests: Iterable[Request], path: str) -> None:
    with open(path, "w") as f:
        for r in requests:
            f.write(r.to_json() + "\n")


# ---------------------------------------------------------------------------
# generator
# ---------------------------------------------------------------------------

@dataclass
class TraceSpec:
    n_requests: int = 23_608
    duration_ms: int = 3_600_000
    seed: int = 0
    # workload mixture — fractions sum to 1
    frac_chat: float = 0.36          # short multi-turn chat
    frac_doc: float = 0.22           # long-document sessions (Kimi-style)
    frac_oneshot: float = 0.42       # cold one-shot requests, no reuse
    # length parameters (tokens)
    chat_turn_mu: float = 6.2        # lognormal of per-turn new input
    chat_turn_sigma: float = 0.8
    doc_len_mu: float = 8.9          # lognormal of document length
    doc_len_sigma: float = 0.7
    out_mu: float = 4.7              # lognormal of output length (mean ≈ 182)
    out_sigma: float = 1.0
    # session structure
    n_system_prompts: int = 24       # hot shared prefixes
    system_prompt_blocks: tuple = (1, 13)   # uniform range
    zipf_s: float = 2.0              # popularity skew of system prompts
    chat_session_turns: tuple = (1, 6)
    doc_session_turns: tuple = (1, 3)
    max_input_tokens: int = 131_072


def generate_trace(spec: TraceSpec = TraceSpec()) -> list[Request]:
    """Synthesise a trace matching the paper's §4 statistics.

    Structure: sessions draw a (hot, Zipf-weighted) system prompt prefix;
    successive turns in a session extend the same hash chain (previous input
    + previous output + new input), which is exactly how real multi-turn
    reuse produces identical prefix hash ids.
    """
    rng = np.random.default_rng(spec.seed)
    next_hash = 0

    def fresh(n: int) -> list[int]:
        nonlocal next_hash
        ids = list(range(next_hash, next_hash + n))
        next_hash += n
        return ids

    # hot system prompts — Zipf popularity (Figure 6's heavy head)
    sys_prompts = [fresh(int(rng.integers(*spec.system_prompt_blocks)))
                   for _ in range(spec.n_system_prompts)]
    zipf_w = 1.0 / np.arange(1, spec.n_system_prompts + 1) ** spec.zipf_s
    zipf_w /= zipf_w.sum()

    requests: list[Request] = []
    rid = 0

    def out_len() -> int:
        return int(np.clip(rng.lognormal(spec.out_mu, spec.out_sigma), 1, 4096))

    def emit(ts: int, chain: list[int], in_tokens: int) -> Request:
        nonlocal rid
        in_tokens = min(in_tokens, spec.max_input_tokens)
        n_blocks = max(math.ceil(in_tokens / BLOCK_TOKENS), 1)
        # extend the chain with fresh tail blocks to cover the input
        if n_blocks > len(chain):
            chain = chain + fresh(n_blocks - len(chain))
        r = Request(req_id=rid, timestamp=ts, input_length=in_tokens,
                    output_length=out_len(), hash_ids=chain[:n_blocks])
        rid += 1
        requests.append(r)
        return r

    n = spec.n_requests
    kinds = rng.choice(3, size=n, p=[spec.frac_chat, spec.frac_doc,
                                     spec.frac_oneshot])
    # sessions arrive as Poisson process; turns follow with think-time gaps
    budget = {0: int((kinds == 0).sum()), 1: int((kinds == 1).sum()),
              2: int((kinds == 2).sum())}

    def session_start() -> int:
        return int(rng.uniform(0, spec.duration_ms * 0.97))

    # --- chat sessions ---
    left = budget[0]
    while left > 0:
        turns = min(int(rng.integers(*spec.chat_session_turns)), left)
        left -= turns
        ts = session_start()
        sp = sys_prompts[rng.choice(spec.n_system_prompts, p=zipf_w)]
        chain = list(sp)
        total_in = len(chain) * BLOCK_TOKENS
        for _ in range(turns):
            new_in = int(np.clip(rng.lognormal(spec.chat_turn_mu,
                                               spec.chat_turn_sigma), 16, 32768))
            total_in += new_in
            r = emit(ts, chain, total_in)
            chain = list(r.hash_ids)
            # next turn context = this turn's input + its output
            total_in = r.input_length + r.output_length
            ts += int(rng.exponential(45_000)) + r.output_length * 40

    # --- long-document sessions ---
    left = budget[1]
    while left > 0:
        turns = min(int(rng.integers(*spec.doc_session_turns)), left)
        left -= turns
        ts = session_start()
        sp = sys_prompts[rng.choice(spec.n_system_prompts, p=zipf_w)]
        doc = int(np.clip(rng.lognormal(spec.doc_len_mu, spec.doc_len_sigma),
                          2048, spec.max_input_tokens))
        chain = list(sp)
        total_in = len(chain) * BLOCK_TOKENS + doc
        for _ in range(turns):
            r = emit(ts, chain, total_in)
            chain = list(r.hash_ids)
            total_in = r.input_length + r.output_length \
                + int(rng.lognormal(5.5, 0.8))  # follow-up question
            ts += int(rng.exponential(60_000)) + r.output_length * 40

    # --- one-shot cold requests ---
    for _ in range(budget[2]):
        ts = session_start()
        L = int(np.clip(rng.lognormal(7.6, 1.3), 32, spec.max_input_tokens))
        emit(ts, [], L)

    # session turns can run past the window; the trace is a 1-hour sample
    requests = [r for r in requests if r.timestamp <= spec.duration_ms]
    requests.sort(key=lambda r: r.timestamp)
    for i, r in enumerate(requests):
        r.req_id = i
    return requests


def simulated_requests(n: int, input_len: int, output_len: int = 512,
                       cache_ratio: float = 0.5, rps: float = 1.0,
                       seed: int = 0) -> list[Request]:
    """§8.1.2 simulated data: fixed lengths, fixed prefix-cache ratio,
    Poisson arrivals at ``rps`` requests/second."""
    rng = np.random.default_rng(seed)
    n_blocks = math.ceil(input_len / BLOCK_TOKENS)
    shared_blocks = int(n_blocks * cache_ratio)
    gaps = rng.exponential(1000.0 / max(rps, 1e-9), size=n)
    ts = np.cumsum(gaps).astype(int)
    out: list[Request] = []
    next_hash = 10**9  # disjoint from generator ids
    # requests pair-share prefixes so cache_ratio of blocks hit on 2nd use
    shared_pool: list[list[int]] = []
    for i in range(n):
        if shared_blocks and shared_pool and rng.random() < 0.5:
            prefix = shared_pool[int(rng.integers(len(shared_pool)))]
        else:
            prefix = list(range(next_hash, next_hash + shared_blocks))
            next_hash += shared_blocks
            if shared_blocks:
                shared_pool.append(prefix)
        tail = list(range(next_hash, next_hash + n_blocks - shared_blocks))
        next_hash += n_blocks - shared_blocks
        out.append(Request(req_id=i, timestamp=int(ts[i]),
                           input_length=input_len, output_length=output_len,
                           hash_ids=prefix + tail))
    return out


def trace_stats(requests: list[Request]) -> dict:
    ins = np.array([r.input_length for r in requests])
    outs = np.array([r.output_length for r in requests])
    all_blocks: dict[int, int] = {}
    for r in requests:
        for h in r.hash_ids:
            all_blocks[h] = all_blocks.get(h, 0) + 1
    counts = np.array(list(all_blocks.values()))
    return dict(
        n=len(requests),
        avg_input=float(ins.mean()),
        avg_output=float(outs.mean()),
        p50_input=float(np.percentile(ins, 50)),
        p99_input=float(np.percentile(ins, 99)),
        n_unique_blocks=len(all_blocks),
        frac_blocks_single_use=float((counts == 1).mean()),
        max_block_hits=int(counts.max()),
        # upper bound on reuse: hits beyond first use / total block touches
        max_reuse=float((counts - 1).sum() / counts.sum()),
    )
