"""File-backed SSD KVCache tier with async layer-wise prefetch (§5.2).

``SSDBlockStore`` is the byte store behind ``HostKVPool``'s SSD tier: one
data file of fixed-size slots, one 512-token block per slot, laid out
layer-major so a block can be read back layer by layer — the on-disk
mirror of the §5.2 load stream. Demotions are *staged* in memory and
written as one sequential batch every ``writeback_batch`` blocks (the
same batching ``TieredCachePool`` accounts for); a crash before the flush
loses only staged blocks, which simply fall back to recompute.

Every slot carries a header with a magic tag, the block key, and one
CRC32 per layer, so reads are truncation- and corruption-safe: a torn
write, a truncated file, or flipped payload bits make ``read_block`` /
``read_layer`` return ``None`` — never wrong KV bytes. Callers treat a
failed read as a cache miss and recompute (the engine also discards the
block's metadata so the hierarchy stops claiming it).

``AsyncPrefetcher`` is the §5.2 "launch the next layer's load" queue: a
daemon thread that services (block, layer) reads in layer-major order —
layer l of every requested block lands before layer l+1 — while the
prefill worker recomputes the head chunks of the prefix on the
accelerator. ``PrefetchHandle.wait()`` is the paper's wait-before-attend
barrier. ``read_bw`` throttles reads to a target bandwidth so the
load-vs-compute split stays meaningful on hosts whose page cache would
otherwise hide the tier entirely (and so benchmarks can dial the
SSD:compute ratio the paper's SATA/NVMe scenarios explore).
"""
from __future__ import annotations

import json
import mmap
import os
import queue
import struct
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

_MAGIC = b"MKV1"
_HDR_FIXED = struct.Struct("<4sQI")     # magic, block key, n_layers


class SSDBlockStore:
    """Slotted, checksummed, file-backed KV block store.

    One block = the per-layer (k, v) arrays of 512 tokens, shape
    ``(L, T, KV, Dh)`` each. The slot payload is layer-major:
    ``k[0] v[0] k[1] v[1] ...`` so ``read_layer`` is one contiguous read.
    Shapes/dtype are inferred from the first ``put`` and persisted to
    ``meta.json`` next to the data file.
    """

    def __init__(self, directory: str, *, writeback_batch: int = 8,
                 read_bw: Optional[float] = None,
                 write_bw: Optional[float] = None,
                 fsync: bool = False) -> None:
        os.makedirs(directory, exist_ok=True)
        self.dir = directory
        self.path = os.path.join(directory, "kvblocks.dat")
        self.writeback_batch = max(int(writeback_batch), 1)
        self.read_bw = read_bw
        self.write_bw = write_bw
        self.fsync = fsync
        self._fd = os.open(self.path, os.O_RDWR | os.O_CREAT, 0o644)
        self._lock = threading.RLock()
        self._mm: Optional[mmap.mmap] = None
        self._mm_size = 0
        #: guarded_by self._lock
        self._offsets: dict[int, int] = {}      # key -> slot offset (on disk)
        #: guarded_by self._lock
        self._free: list[int] = []              # reusable slot offsets
        #: guarded_by self._lock
        self._staged: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self._shape: Optional[tuple] = None     # per-array (L, T, KV, Dh)
        self._dtype: Optional[np.dtype] = None
        # stats
        self.blocks_written = 0
        self.blocks_read = 0
        self.layer_reads = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self.n_flushes = 0                      # batched write operations
        self.read_failures = 0                  # checksum / truncation
        self._read_s_ema: Optional[float] = None  # seconds per block read
        self._recover()

    def _recover(self) -> None:
        """Reopen an existing store: restore geometry from ``meta.json``
        and re-index flushed slots by scanning their headers, so a crash
        loses only the STAGED blocks (payload validity is still checked
        per-read by the layer CRCs). Slots with torn headers become free
        slots; an unreadable/absent meta.json means a fresh store."""
        meta_path = os.path.join(self.dir, "meta.json")
        size = os.fstat(self._fd).st_size
        if size == 0 or not os.path.exists(meta_path):
            return
        try:
            with open(meta_path) as f:
                meta = json.load(f)
            shape, dtype = tuple(meta["shape"]), np.dtype(meta["dtype"])
        except (ValueError, KeyError, TypeError):
            return                              # torn meta: treat as fresh
        self._set_shape(np.empty(shape, dtype))
        with self._lock:
            for off in range(0, size - self._slot_size + 1, self._slot_size):
                raw = self._read_at(off, self._hdr_size)
                if raw is None:
                    break
                magic, key, L = _HDR_FIXED.unpack_from(raw)
                if magic == _MAGIC and L == shape[0] \
                        and key not in self._offsets:
                    self._offsets[key] = off
                else:
                    self._free.append(off)

    # ---- geometry ------------------------------------------------------
    def _set_shape(self, k: np.ndarray) -> None:
        self._shape = tuple(k.shape)
        self._dtype = k.dtype
        self._layer_bytes = int(np.prod(self._shape[1:])) * k.dtype.itemsize
        L = self._shape[0]
        self._hdr_size = _HDR_FIXED.size + 4 * L    # + one CRC32 per layer
        self._slot_size = self._hdr_size + 2 * L * self._layer_bytes
        with open(os.path.join(self.dir, "meta.json"), "w") as f:
            json.dump(dict(shape=list(self._shape), dtype=str(self._dtype),
                           slot_size=self._slot_size), f)

    @property
    def n_layers(self) -> int:
        return self._shape[0] if self._shape else 0

    @property
    def block_bytes(self) -> int:
        """Payload bytes of one block (k + v, all layers)."""
        return 2 * self.n_layers * self._layer_bytes if self._shape else 0

    @property
    def read_s_ema(self) -> Optional[float]:
        """Measured seconds-per-block read EMA (None until the first
        blocking read) — what closes the modeled-vs-measured loop: feed it
        to ``CostModel.calibrate_ssd_read`` / ``Messenger.set_ssd_bw``."""
        return self._read_s_ema

    def est_block_read_s(self, default_bw: float = 500e6) -> float:
        """Expected seconds to read one block: measured EMA when we have
        one, else the throttle bandwidth, else a SATA-class default."""
        if self._read_s_ema is not None:
            return self._read_s_ema
        if not self._shape:
            return 0.0
        bw = self.read_bw if self.read_bw else default_bw
        return self.block_bytes / bw

    # ---- residency -----------------------------------------------------
    def __contains__(self, key: int) -> bool:
        with self._lock:
            return key in self._offsets or key in self._staged

    def __len__(self) -> int:
        with self._lock:
            return len(self._offsets) + len(self._staged)

    @property
    def staged_blocks(self) -> int:
        with self._lock:
            return len(self._staged)

    def keys(self) -> list[int]:
        """Keys with flushed on-disk slots (staged blocks excluded)."""
        with self._lock:
            return list(self._offsets)

    # ---- write path ----------------------------------------------------
    def put(self, key: int, k: np.ndarray, v: np.ndarray) -> None:
        """Stage one block for write-back; flushes a full batch inline."""
        with self._lock:
            if self._shape is None:
                self._set_shape(np.asarray(k))
            self._staged[key] = (np.ascontiguousarray(k),
                                 np.ascontiguousarray(v))
            if len(self._staged) >= self.writeback_batch:
                self._flush_locked()

    def flush(self) -> int:
        """Force the partial write-back batch out; returns blocks written."""
        with self._lock:
            return self._flush_locked()

    def _flush_locked(self) -> int:
        if not self._staged:
            return 0
        staged, self._staged = self._staged, {}
        total = 0
        for key, (k, v) in staged.items():
            off = self._alloc_slot_locked()
            buf = self._encode(key, k, v)
            os.pwrite(self._fd, buf, off)
            self._offsets[key] = off
            self.blocks_written += 1
            total += len(buf)
        self.bytes_written += total
        self.n_flushes += 1
        if self.fsync:
            os.fsync(self._fd)
        if self.write_bw:
            time.sleep(total / self.write_bw)
        return len(staged)

    def _alloc_slot_locked(self) -> int:
        """Next slot offset for a flush. Caller holds ``self._lock``."""
        if self._free:
            return self._free.pop()
        end = (max(self._offsets.values()) + self._slot_size
               if self._offsets else 0)
        return end

    def _encode(self, key: int, k: np.ndarray, v: np.ndarray) -> bytes:
        L = self._shape[0]
        parts, crcs = [], []
        for l in range(L):
            kb = np.ascontiguousarray(k[l]).tobytes()
            vb = np.ascontiguousarray(v[l]).tobytes()
            crcs.append(zlib.crc32(kb + vb) & 0xFFFFFFFF)
            parts.append(kb)
            parts.append(vb)
        hdr = _HDR_FIXED.pack(_MAGIC, key & (2**64 - 1), L) \
            + struct.pack(f"<{L}I", *crcs)
        return hdr + b"".join(parts)

    def delete(self, key: int) -> None:
        with self._lock:
            if self._staged.pop(key, None) is not None:
                return
            off = self._offsets.pop(key, None)
            if off is not None:
                self._free.append(off)

    # ---- read path -----------------------------------------------------
    def _read_at(self, off: int, n: int) -> Optional[bytes]:
        """mmap fast path (remapped as the file grows); a request past EOF
        is a truncated slot → None."""
        end = off + n
        if end > self._mm_size:
            size = os.fstat(self._fd).st_size
            if end > size:
                return None
            if self._mm is not None:
                self._mm.close()
            self._mm = mmap.mmap(self._fd, size, prot=mmap.PROT_READ)
            self._mm_size = size
        return self._mm[off:end]

    def _slot_header_locked(self, key: int) \
            -> Optional[tuple[int, list[int]]]:
        """Validated (slot offset, per-layer CRCs) of an on-disk block.
        Caller holds ``self._lock``."""
        off = self._offsets.get(key)
        if off is None:
            return None
        raw = self._read_at(off, self._hdr_size)
        if raw is None:
            return None
        magic, hkey, L = _HDR_FIXED.unpack_from(raw)
        if magic != _MAGIC or hkey != key & (2**64 - 1) \
                or L != self._shape[0]:
            return None
        crcs = list(struct.unpack_from(f"<{L}I", raw, _HDR_FIXED.size))
        return off, crcs

    def _decode_layer(self, raw: bytes) -> tuple[np.ndarray, np.ndarray]:
        half = self._layer_bytes
        shape = self._shape[1:]
        k = np.frombuffer(raw[:half], dtype=self._dtype).reshape(shape)
        v = np.frombuffer(raw[half:], dtype=self._dtype).reshape(shape)
        return k, v

    def read_layer(self, key: int, layer: int) \
            -> Optional[tuple[np.ndarray, np.ndarray]]:
        """One layer's (k, v) of one block — the §5.2 load-stream unit.
        ``None`` on any integrity failure (missing, truncated, corrupt)."""
        t0 = time.monotonic()
        with self._lock:
            st = self._staged.get(key)
            if st is not None:
                k, v = st
                return np.asarray(k[layer]), np.asarray(v[layer])
            hdr = self._slot_header_locked(key)
            if hdr is None:
                if key in self._offsets:
                    self.read_failures += 1
                return None
            off, crcs = hdr
            pair = 2 * self._layer_bytes
            raw = self._read_at(off + self._hdr_size + layer * pair, pair)
            if raw is None or (zlib.crc32(raw) & 0xFFFFFFFF) != crcs[layer]:
                self.read_failures += 1
                return None
            self.layer_reads += 1
            self.bytes_read += pair
        self._throttle(pair, t0)
        return self._decode_layer(raw)

    def read_block(self, key: int) \
            -> Optional[tuple[np.ndarray, np.ndarray]]:
        """Whole-block (k, v), layer-verified; ``None`` on any failure."""
        L = self.n_layers
        if L == 0 or key not in self:
            return None
        t0 = time.monotonic()
        ks, vs = [], []
        for l in range(L):
            pair = self.read_layer(key, l)
            if pair is None:
                return None
            ks.append(pair[0])
            vs.append(pair[1])
        self.blocks_read += 1
        # feed the split-search EMA from BLOCKING reads only: here the wall
        # time is genuinely the store's cost. Prefetch-thread layer reads
        # deliberately don't count — their elapsed time includes the GIL /
        # scheduling gaps of the compute they overlap, which would inflate
        # the estimate and push the split toward pure recompute.
        self.note_measured_read(time.monotonic() - t0)
        return np.stack(ks), np.stack(vs)

    def _throttle(self, nbytes: int, t0: float) -> None:
        if self.read_bw:
            remain = nbytes / self.read_bw - (time.monotonic() - t0)
            if remain > 0:
                time.sleep(remain)

    def note_measured_read(self, seconds_per_block: float) -> None:
        """Fold one measured block-read time into the split-search EMA."""
        self._read_s_ema = seconds_per_block if self._read_s_ema is None \
            else 0.7 * self._read_s_ema + 0.3 * seconds_per_block

    # ---- reporting / lifecycle ----------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return dict(blocks=len(self._offsets), staged=len(self._staged),
                        blocks_written=self.blocks_written,
                        blocks_read=self.blocks_read,
                        layer_reads=self.layer_reads,
                        bytes_written=self.bytes_written,
                        bytes_read=self.bytes_read,
                        n_flushes=self.n_flushes,
                        read_failures=self.read_failures,
                        file_bytes=os.fstat(self._fd).st_size)

    def close(self) -> None:
        with self._lock:
            self._flush_locked()
            if self._mm is not None:
                self._mm.close()
                self._mm = None
            if self._fd >= 0:
                os.close(self._fd)
                self._fd = -1

    def __del__(self):  # best-effort; explicit close() preferred
        try:
            if getattr(self, "_fd", -1) >= 0:
                os.close(self._fd)
                self._fd = -1
        except OSError:
            pass


# ---------------------------------------------------------------------------
# async layer-wise prefetch
# ---------------------------------------------------------------------------


@dataclass
class PrefetchHandle:
    """In-flight layer-wise loads of one block set.

    ``result(key)`` is the assembled (k, v) for a fully verified block,
    ``None`` while loading or after any layer of it failed; ``failed``
    lists blocks that hit a checksum/truncation error. ``layer_log``
    records (key, layer, t_done) in completion order — the §5.2 timeline
    the benchmark plots against compute chunks.
    """
    keys: list[int]
    _bufs: dict = field(default_factory=dict)      # key -> (k, v) buffers
    _layers_done: dict = field(default_factory=dict)
    failed: set = field(default_factory=set)
    layer_log: list = field(default_factory=list)
    _t0: float = field(default_factory=time.monotonic)
    _remaining: int = 0
    _done: threading.Event = field(default_factory=threading.Event)
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def _deliver(self, key: int, layer: int, pair, n_layers: int) -> None:
        with self._lock:
            if pair is None:
                self.failed.add(key)
                self._bufs.pop(key, None)
            elif key not in self.failed:
                if key not in self._bufs:
                    k0 = pair[0]
                    shape = (n_layers,) + k0.shape
                    self._bufs[key] = (np.empty(shape, k0.dtype),
                                       np.empty(shape, k0.dtype))
                self._bufs[key][0][layer] = pair[0]
                self._bufs[key][1][layer] = pair[1]
                self._layers_done[key] = self._layers_done.get(key, 0) + 1
            self.layer_log.append((key, layer,
                                   time.monotonic() - self._t0))
            self._remaining -= 1
            if self._remaining <= 0:
                self._done.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """§5.2 wait-before-attend barrier for the whole fetch."""
        return self._done.wait(timeout)

    def result(self, key: int):
        """(k, v) for a complete, verified block; else None."""
        with self._lock:
            if key in self.failed:
                return None
            bufs = self._bufs.get(key)
            if bufs is None:
                return None
            n = self._layers_done.get(key, 0)
            return bufs if n == bufs[0].shape[0] else None


class AsyncPrefetcher:
    """Daemon thread servicing layer-major block loads off the store.

    ``fetch(keys)`` enqueues layer 0 of every block, then layer 1, … so
    arrival order matches the §5.2 load stream; the caller overlaps its
    head-chunk recompute and joins on ``PrefetchHandle.wait()``.

    ``sources`` maps a key to an alternative read source — any object
    with ``n_layers`` and ``read_layer(key, layer)`` — which is how a
    peer node's store streams through the SAME layer-major queue as local
    blocks (the global pool's cross-node fetch path). Keys whose source
    reports zero layers (e.g. a peer that never wrote a block) fail
    immediately rather than hanging the handle.
    """

    def __init__(self, store: SSDBlockStore) -> None:
        self.store = store
        self._q: queue.Queue = queue.Queue()
        self._lock = threading.Lock()   # serialises fetch() vs close()
        self._closed = False            #: guarded_by self._lock
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="repro-kv-prefetch")
        self._thread.start()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def fetch(self, keys: list[int],
              sources: Optional[dict] = None) -> PrefetchHandle:
        h = PrefetchHandle(keys=list(keys))
        tasks = []
        for key in keys:
            src = (sources or {}).get(key, self.store)
            L = src.n_layers
            if L == 0:
                h.failed.add(key)
                continue
            tasks.append((key, src, L))
        with self._lock:
            # a fetch against a closed prefetcher must FAIL the handle
            # immediately: its thread is (being) joined, so enqueued tasks
            # would never be serviced and wait() would hang forever
            if self._closed or not tasks:
                h.failed.update(k for k, _, _ in tasks)
                h._done.set()
                return h
            h._remaining = sum(L for _, _, L in tasks)
            for layer in range(max(L for _, _, L in tasks)):
                for key, src, L in tasks:
                    if layer < L:
                        self._q.put((h, key, layer, L, src))
        return h

    def _run(self) -> None:
        while True:
            task = self._q.get()
            if task is None:
                return
            h, key, layer, L, src = task
            # after close() the remaining queue drains as failures without
            # touching the store (it is about to be closed underneath us);
            # every in-flight handle still completes, degrading to recompute
            with self._lock:
                closed = self._closed
            if closed or key in h.failed:
                h._deliver(key, layer, None, L)
                continue
            try:
                pair = src.read_layer(key, layer)
            except Exception:            # never let the thread die mid-fetch
                pair = None
            h._deliver(key, layer, pair, L)

    def close(self) -> None:
        """Deterministic shutdown: mark closed (new fetches fail fast, the
        pending queue drains as failures instead of reading a store that is
        about to close), then join the thread — no timeout, no leaked
        thread. Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._q.put(None)           # sentinel: queued work fails fast
        self._thread.join()
