"""GlobalBlockDirectory — the cluster-wide KVCache pool's metadata plane.

The paper's Figure-3 pool spans the DRAM and SSD of *every* node, but the
per-instance ``TieredCachePool``/``SSDBlockStore`` of PRs 1–3 keep each
node's tiers private: a block demoted on node A is invisible to a request
routed to node B, forcing exactly the recompute the KVCache-centric
architecture exists to avoid. This directory is the missing piece — a
Conductor-side registry of which nodes hold which block in which tier, so
prefill routing can propose a fourth arm (fetch a prefix off a *peer's*
SSD, priced as SSD read + network hop) and the serving engine can resolve
a local miss to a remote store.

The directory is deliberately *advisory*: it answers "who probably holds
this block", never "these bytes are valid". Every consumer re-verifies at
fetch time (per-layer CRCs on store reads; residency re-checks on DRAM
reads) and degrades to recompute when the directory turns out stale —
wrong bytes are impossible by construction, wasted fetches merely cost
the latency the cost model charged anyway.

Invariants (asserted by ``tests/test_global_pool.py`` property tests):

  * at most ONE registration per (node, key) — re-registering updates the
    tier in place, it never duplicates an owner;
  * ``unregister``/``drop_node`` leave no dangling owners: a lookup never
    returns a node that dropped the block;
  * a bound pool's directory view equals its actual residency after any
    interleaving of insert/lookup(promote)/demote/discard.

``bind(node, pool)`` wires a ``TieredCachePool``'s tier-event hooks
(chaining with any hooks a byte-holder like ``HostKVPool`` installed
first) and seeds the pool's current residency, so simulator instances and
serving pools publish moves automatically. All methods are thread-safe:
the engine's prefetch thread may read while the serve loop writes.
"""
from __future__ import annotations

import threading
from typing import Iterable, Optional

TIERS = ("dram", "ssd")


def select_owner(cands):
    """Pick the (node, tier) to fetch from, or None. DRAM owners are
    preferred (a peer-DRAM read skips the SSD media time); ties break on
    the smallest node id for determinism. Shared by the in-process
    directory and the wire-protocol ``RemoteDirectory`` so both halves
    of the cluster agree on owner choice."""
    cands = list(cands)
    if not cands:
        return None
    return min(cands, key=lambda nt: (nt[1] != "dram", nt[0]))


def bind_pool(directory, node, pool) -> None:
    """Publish a ``TieredCachePool``'s residency into ``directory``:
    seed the current state, then chain the tier-event hooks (preserving
    hooks a byte-holder installed first) so every future move is
    mirrored. ``directory`` only needs ``register``/``unregister`` —
    works for both the shared-object and remote-client directories."""
    for key in pool.blocks:
        directory.register(key, node, "dram")
    for key in pool.ssd.blocks:
        directory.register(key, node, "ssd")
    prev_insert = pool.on_insert
    prev_demote = pool.on_demote
    prev_promote = pool.on_promote
    prev_drop = pool.on_drop

    def on_insert(key, tier):
        if prev_insert is not None:
            prev_insert(key, tier)
        directory.register(key, node, tier)

    def on_demote(key):
        if prev_demote is not None:
            prev_demote(key)
        directory.register(key, node, "ssd")

    def on_promote(key, count_read):
        if prev_promote is not None:
            prev_promote(key, count_read)
        directory.register(key, node, "dram")

    def on_drop(key):
        if prev_drop is not None:
            prev_drop(key)
        directory.unregister(key, node)

    pool.on_insert = on_insert
    pool.on_demote = on_demote
    pool.on_promote = on_promote
    pool.on_drop = on_drop


class GlobalBlockDirectory:
    """Block key -> {node: tier} ownership map for one serving cluster."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._owners: dict[int, dict] = {}  #: guarded_by self._lock
        self.n_registers = 0                #: guarded_by self._lock
        self.n_unregisters = 0              #: guarded_by self._lock

    # ---- writes --------------------------------------------------------
    def register(self, key: int, node, tier: str) -> None:
        """Record that ``node`` holds ``key`` in ``tier``. Idempotent per
        (node, key): a re-register moves the tier, never adds an owner."""
        if tier not in TIERS:
            raise ValueError(f"unknown tier {tier!r}; tiers: {list(TIERS)}")
        with self._lock:
            self._owners.setdefault(key, {})[node] = tier
            self.n_registers += 1

    def unregister(self, key: int, node) -> bool:
        """Drop ``node``'s claim on ``key`` (no-op if absent)."""
        with self._lock:
            holders = self._owners.get(key)
            if holders is None or node not in holders:
                return False
            del holders[node]
            if not holders:
                del self._owners[key]
            self.n_unregisters += 1
            return True

    def drop_node(self, node) -> int:
        """Remove every claim of a departed node; returns claims dropped."""
        with self._lock:
            dead = [k for k, h in self._owners.items() if node in h]
            for k in dead:
                self.unregister(k, node)
            return len(dead)

    # ---- reads ---------------------------------------------------------
    def holders(self, key: int) -> dict:
        with self._lock:
            return dict(self._owners.get(key, {}))

    def nodes_with(self, key: int, tier: Optional[str] = None) -> list:
        """Nodes holding ``key`` (optionally restricted to one tier)."""
        with self._lock:
            h = self._owners.get(key, {})
            return sorted(n for n, t in h.items() if tier is None or t == tier)

    def pick_owner(self, key: int, exclude: Iterable = (),
                   among: Optional[Iterable] = None):
        """(node, tier) to fetch ``key`` from, or None. DRAM owners are
        preferred (a peer-DRAM read skips the SSD media time); ties break
        on the smallest node id for determinism."""
        exclude = set(exclude)
        among = None if among is None else set(among)
        with self._lock:
            cands = [(n, t) for n, t in self._owners.get(key, {}).items()
                     if n not in exclude and (among is None or n in among)]
        return select_owner(cands)

    def best_ssd_extension(self, hash_ids: list, start: int = 0,
                           exclude: Iterable = ()) -> tuple:
        """Longest contiguous run ``hash_ids[start:start+k]`` held on ONE
        peer node's SSD; returns (k, node) with k == 0 when no peer
        extends the chain. Single-source keeps the arm's transfer a single
        FIFO-pipe enqueue, mirroring ``peer_fetch_arm``."""
        if start >= len(hash_ids):
            return 0, None
        exclude = set(exclude)
        best_k, best_node = 0, None
        for node in self.nodes_with(hash_ids[start], tier="ssd"):
            if node in exclude:
                continue
            k = 0
            with self._lock:
                for h in hash_ids[start:]:
                    if self._owners.get(h, {}).get(node) != "ssd":
                        break
                    k += 1
            if k > best_k:
                best_k, best_node = k, node
        return best_k, best_node

    def __len__(self) -> int:
        with self._lock:
            return len(self._owners)

    def snapshot(self) -> dict:
        """Deep copy of the ownership map (test/debug aid)."""
        with self._lock:
            return {k: dict(h) for k, h in self._owners.items()}

    def stats(self) -> dict:
        with self._lock:
            n_ssd = sum(1 for h in self._owners.values()
                        for t in h.values() if t == "ssd")
            n_dram = sum(1 for h in self._owners.values()
                         for t in h.values() if t == "dram")
            return dict(keys=len(self._owners), dram_claims=n_dram,
                        ssd_claims=n_ssd, registers=self.n_registers,
                        unregisters=self.n_unregisters)

    # ---- pool binding --------------------------------------------------
    def bind(self, node, pool) -> None:
        """Publish a ``TieredCachePool``'s residency: seed the current
        state, then chain the tier-event hooks (preserving hooks a
        byte-holder installed first) so every future move is mirrored."""
        bind_pool(self, node, pool)
