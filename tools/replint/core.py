"""Driver infrastructure for repro-lint.

A checker is ``check(ctx) -> list[Finding]`` where ``ctx`` is a
:class:`ModuleCtx` (path, source, raw lines, parsed tree with parent
links).  ``lint_paths`` walks the given files/directories, runs every
registered rule, and filters findings through per-line suppression
comments:

    do_racy_thing()  # replint: ignore[guarded-by] -- snapshot is advisory

A suppression on its own line applies to the next line.  Several rules
can share one comment: ``# replint: ignore[guarded-by, host-alias]``.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass


@dataclass(frozen=True)
class Finding:
    path: str           # repo-relative, forward slashes
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    @property
    def baseline_key(self) -> str:
        # line numbers drift too easily to key on; path+rule+message is
        # stable across unrelated edits to the same file
        return f"{self.path}::{self.rule}::{self.message}"


@dataclass
class ModuleCtx:
    path: str
    src: str
    lines: list[str]    # 1-indexed via lines[i-1]
    tree: ast.Module


# ---------------------------------------------------------------- helpers

def add_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._replint_parent = node  # type: ignore[attr-defined]


def parent(node: ast.AST):
    return getattr(node, "_replint_parent", None)


def dotted(node) -> str | None:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def is_self_attr(node, name: str | None = None) -> bool:
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and (name is None or node.attr == name))


def own_nodes(func: ast.AST):
    """Walk a function body without descending into nested defs/lambdas."""
    todo = list(ast.iter_child_nodes(func))
    while todo:
        node = todo.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        todo.extend(ast.iter_child_nodes(node))


def functions_in(tree: ast.Module):
    """Every FunctionDef/AsyncFunctionDef in the module, at any depth."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def classes_in(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            yield node


def names_in(node) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


# ------------------------------------------------------------ suppressions

_SUPPRESS_RE = re.compile(r"#\s*replint:\s*ignore\[([\w\s,\-]+)\]")


def suppressed_lines(lines: list[str]) -> dict[int, set[str]]:
    """Map line number -> suppressed rule names on that line."""
    out: dict[int, set[str]] = {}
    for i, ln in enumerate(lines, 1):
        m = _SUPPRESS_RE.search(ln)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        before = ln[:m.start()].rstrip()
        # a standalone comment line guards the line that follows it
        target = i if before.rstrip("#").strip() else i + 1
        out.setdefault(target, set()).update(rules)
    return out


# --------------------------------------------------------------- baseline

def load_baseline(path: str) -> set[str]:
    if not os.path.exists(path):
        return set()
    keys = set()
    with open(path, encoding="utf-8") as fh:
        for ln in fh:
            ln = ln.strip()
            if ln and not ln.startswith("#"):
                keys.add(ln)
    return keys


def write_baseline(path: str, findings: list[Finding]) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        fh.write("# repro-lint baseline: grandfathered findings, one "
                 "baseline key per line.\n")
        fh.write("# Target state is an EMPTY baseline -- fix, don't "
                 "accumulate.\n")
        for f in sorted({f.baseline_key for f in findings}):
            fh.write(f + "\n")


# ----------------------------------------------------------------- driver

def _rules():
    # imported lazily so ``from tools.replint.core import ...`` never
    # cycles with the checker modules
    from tools.replint import (guarded_by, host_alias, purity, refcount,
                               socket_pair, stop_iteration)
    return [
        (guarded_by.RULE, guarded_by.check),
        (host_alias.RULE, host_alias.check),
        (stop_iteration.RULE, stop_iteration.check),
        (refcount.RULE, refcount.check),
        (socket_pair.RULE, socket_pair.check),
        (purity.RULE, purity.check),
    ]


RULES = [name for name, _ in _rules()]


def iter_py_files(paths: list[str]):
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if not d.startswith(".")
                                 and d != "__pycache__")
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def lint_file(path: str, rules=None) -> list[Finding]:
    rel = os.path.relpath(path).replace(os.sep, "/")
    with open(path, encoding="utf-8") as fh:
        src = fh.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding(rel, e.lineno or 1, "parse-error",
                        f"could not parse: {e.msg}")]
    add_parents(tree)
    ctx = ModuleCtx(rel, src, src.splitlines(), tree)
    suppressed = suppressed_lines(ctx.lines)
    out: list[Finding] = []
    for rule, check in (rules or _rules()):
        for f in check(ctx):
            if rule in suppressed.get(f.line, ()):
                continue
            out.append(f)
    return out


def lint_paths(paths: list[str]) -> tuple[list[Finding], int]:
    """Lint every .py file under ``paths``; returns (findings, n_files)."""
    findings: list[Finding] = []
    n = 0
    for path in iter_py_files(paths):
        n += 1
        findings.extend(lint_file(path))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, n
