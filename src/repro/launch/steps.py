"""Step functions + ShapeDtypeStruct input specs for every
(architecture × input shape) combination — what the dry-run lowers.

Shapes (assignment):
  train_4k     seq 4,096   global_batch 256   train_step
  prefill_32k  seq 32,768  global_batch 32    prefill_step (chunk-causal)
  decode_32k   seq 32,768  global_batch 128   serve_step (1 new token)
  long_500k    seq 524,288 global_batch 1     serve_step (sub-quadratic only)

Sharding (DESIGN.md §7): weights TP on 'model' × FSDP on 'data',
replicated on 'pod'; activations batch on ('pod','data'); KV caches shard
batch on ('pod','data') and the SEQUENCE dim on 'model' (context-parallel
decode — the memory-bound KV read is what decode rooflines on, so the
sequence is striped across the TP group). ``long_500k`` (batch 1) stripes
the sequence over ('data','model') = all 256 chips instead.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import DTYPE, Dist
from repro.models.mamba import MambaState
from repro.models.transformer import (Caches, KVCache, decode_step,
                                      init_caches, init_params, loss_fn,
                                      prefill)
from repro.training.optim import make_optimizer

SERVE_WINDOW = 8192   # sliding-window serving variant for dense long_500k


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int
    long: bool = False


INPUT_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1, long=True),
}


def applicability(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether this (arch, shape) runs, and how (DESIGN.md §6)."""
    if shape.name != "long_500k":
        return True, ""
    if cfg.max_decode_len and cfg.max_decode_len < shape.seq:
        return False, (f"decoder architecturally capped at "
                       f"{cfg.max_decode_len} tokens — skip")
    if cfg.kind in ("ssm", "hybrid"):
        return True, "constant-state SSM path (+ KV for hybrid attn layers)"
    if cfg.sliding_window:
        return True, f"native SWA window={cfg.sliding_window} (ring cache)"
    return True, f"sliding-window serving variant (--serve-window {SERVE_WINDOW})"


def serve_window(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Effective attention window for a decode shape (0 = full)."""
    if shape.name != "long_500k":
        return cfg.sliding_window
    if cfg.kind in ("ssm", "hybrid"):
        return cfg.sliding_window
    return cfg.sliding_window or SERVE_WINDOW


# ---------------------------------------------------------------------------
# distribution context
# ---------------------------------------------------------------------------

def make_dist(mesh, shape: ShapeSpec) -> Dist:
    batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    if shape.batch == 1:
        batch_axes = ()           # long_500k: nothing to shard on batch
    return Dist(mesh=mesh, batch_axes=batch_axes)


def _batch_spec(dist: Dist, *rest) -> P:
    return dist.batch_spec(*rest)


def _seq_axes(dist: Dist) -> Any:
    """Axes striping a KV-cache sequence dim: 'model', plus 'data'/'pod'
    when the batch doesn't use them (long_500k)."""
    if dist.batch_axes:
        return "model"
    free = tuple(a for a in dist.mesh.axis_names if a != "model")
    return free + ("model",)


# ---------------------------------------------------------------------------
# cache construction (shapes + shardings)
# ---------------------------------------------------------------------------

def cache_shapes(cfg: ModelConfig, shape: ShapeSpec):
    window = serve_window(cfg, shape)
    enc_len = cfg.frontend_tokens if cfg.encoder_layers else 0
    shapes = jax.eval_shape(
        lambda: init_caches(cfg, shape.batch, shape.seq,
                            enc_len=enc_len, window=window))
    return shapes


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, dist: Dist) -> Caches:
    b = dist.batch_axes or None
    if isinstance(b, tuple) and len(b) == 1:
        b = b[0]
    seq = _seq_axes(dist)
    kv = ssm = enc_kv = None
    if cfg.attention_layers:
        lead = (None,)  # stacked layer axis (hybrid: n_per — still one axis)
        kv = KVCache(k=P(*lead, b, seq, None, None),
                     v=P(*lead, b, seq, None, None))
    if cfg.ssm is not None and cfg.kind in ("ssm", "hybrid"):
        if cfg.attn_every:
            ssm = MambaState(ssm=P(None, None, b, "model", None, None),
                             conv=P(None, None, b, None, "model"))
        else:
            ssm = MambaState(ssm=P(None, b, "model", None, None),
                             conv=P(None, b, None, "model"))
    if cfg.encoder_layers:
        enc_kv = KVCache(k=P(None, b, None, None, None),
                         v=P(None, b, None, None, None))
    return Caches(kv=kv, ssm=ssm, enc_kv=enc_kv, length=P())


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec, dist: Dist):
    """Returns (args: dict of ShapeDtypeStruct pytrees, arg_specs: matching
    PartitionSpec pytrees) for the step function of ``shape.kind``."""
    b = dist.batch_axes or None
    if isinstance(b, tuple) and len(b) == 1:
        b = b[0]
    B, S = shape.batch, shape.seq
    f32, i32 = jnp.float32, jnp.int32

    def tok(s):
        return jax.ShapeDtypeStruct((B, s), i32)

    args: dict = {}
    specs: dict = {}
    if shape.kind == "train":
        args["batch"] = {"tokens": tok(S), "labels": tok(S)}
        specs["batch"] = {"tokens": P(b, None), "labels": P(b, None)}
        if cfg.frontend == "patch":
            args["batch"]["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.d_model), f32)
            specs["batch"]["patches"] = P(b, None, "model")
        if cfg.frontend == "audio":
            args["batch"]["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.d_model), f32)
            specs["batch"]["frames"] = P(b, None, "model")
    elif shape.kind == "prefill":
        args["tokens"] = tok(S)
        specs["tokens"] = P(b, None)
        if cfg.frontend == "patch":
            args["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.d_model), f32)
            specs["patches"] = P(b, None, "model")
        if cfg.frontend == "audio":
            args["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.d_model), f32)
            specs["frames"] = P(b, None, "model")
    else:  # decode
        args["tokens"] = tok(1)
        specs["tokens"] = P(b, None)
        args["caches"] = cache_shapes(cfg, shape)
        specs["caches"] = cache_specs(cfg, shape, dist)
    return args, specs


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, dist: Dist):
    _, opt_update = make_optimizer(cfg.optimizer)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, dist))(params)
        new_params, new_opt = opt_update(params, grads, opt_state)
        return loss, new_params, new_opt

    return train_step


def make_prefill_step(cfg: ModelConfig, dist: Dist):
    def prefill_step(params, tokens, extra):
        """``extra``: {} or {'frames': ...} / {'patches': ...} (stub
        modality embeddings)."""
        return prefill(params, tokens, cfg, dist,
                       frames=extra.get("frames"),
                       patches=extra.get("patches"))

    return prefill_step


def make_serve_step(cfg: ModelConfig, dist: Dist, shape: ShapeSpec):
    window = serve_window(cfg, shape)
    # ring buffer when the cache is sized AT the window (windowed serving)
    ring = bool(window) and window < shape.seq

    def serve_step(params, tokens, caches):
        return decode_step(params, tokens, caches, cfg, dist,
                           ring=ring, window_override=window or None)

    return serve_step


# ---------------------------------------------------------------------------
# optimizer state specs
# ---------------------------------------------------------------------------

def opt_state_specs(cfg: ModelConfig, p_specs, param_shapes):
    """OptState sharding: m/v follow the parameter; adafactor's factored v
    (row, col) drop the last / second-to-last parameter axis."""
    from repro.training.optim import OptState

    if cfg.optimizer == "adamw":
        return OptState(step=P(), m=p_specs, v=p_specs)

    is_spec = lambda s: isinstance(s, P)

    def v_spec(spec, shp):
        if shp.ndim < 2:          # unfactored second moment
            return spec
        t = tuple(spec) + (None,) * (shp.ndim - len(tuple(spec)))
        return (P(*t[:-1]), P(*(t[:-2] + t[-1:])))

    v = jax.tree.map(v_spec, p_specs, param_shapes, is_leaf=is_spec)
    return OptState(step=P(), m=p_specs, v=v)


def opt_state_shapes(cfg: ModelConfig, param_shapes):
    from repro.training.optim import make_optimizer as mk
    init, _ = mk(cfg.optimizer)
    return jax.eval_shape(init, param_shapes)
