"""Tests for the repro-lint static-analysis suite (tools/replint).

Fixture policy: every rule has a paired FLAG fixture (must produce at
least one finding of exactly that rule) and a CLEAN fixture (must be
finding-free) under tests/replint_fixtures/. The flag fixtures encode
the repo's real historical bugs — re-introducing the PR-5 missing
``.copy()`` or any of the PR-6 shapes must trip a checker.
"""
import os
import subprocess
import sys

import pytest

from tools.replint.core import (lint_file, lint_paths, load_baseline,
                                suppressed_lines, write_baseline)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "replint_fixtures")

RULE_FIXTURES = [
    ("guarded-by", "guarded_by"),
    ("host-alias", "host_alias"),
    ("stop-iteration", "stop_iteration"),
    ("refcount-pair", "refcount"),
    ("socket-pair", "socket_pair"),
    ("policy-purity", "purity"),
]


def _lint(name):
    return lint_file(os.path.join(FIXTURES, name))


# ---------------------------------------------------------------- fixtures

@pytest.mark.parametrize("rule,stem", RULE_FIXTURES)
def test_flag_fixture_fires(rule, stem):
    findings = _lint(f"{stem}_flag.py")
    assert findings, f"{stem}_flag.py produced no findings"
    assert {f.rule for f in findings} == {rule}, \
        f"unexpected rules: {[(f.rule, f.line) for f in findings]}"


@pytest.mark.parametrize("rule,stem", RULE_FIXTURES)
def test_clean_fixture_is_silent(rule, stem):
    findings = _lint(f"{stem}_clean.py")
    assert findings == [], \
        f"false positives: {[f.render() for f in findings]}"


# -------------------------------------------------- historical bug shapes

def test_pr5_missing_copy_is_caught():
    """DecodeWorker.step without the defensive .copy() (the PR-5 race)."""
    findings = [f for f in _lint("host_alias_flag.py")
                if "block_table" in f.message or "tbl" in f.message]
    assert findings


def test_pr6_bare_stop_iteration_join_is_caught():
    findings = [f for f in _lint("stop_iteration_flag.py")
                if "raise StopIteration" in f.message]
    assert findings


def test_pr6_post_close_enqueue_is_caught():
    """Unlocked check of _closed (check-then-act vs close())."""
    findings = [f for f in _lint("guarded_by_flag.py")
                if "_closed" in f.message]
    assert findings


def test_pre_fix_stage_run_shape_is_caught():
    """MemoryError-only handler around an acquire leaks on other errors."""
    findings = [f for f in _lint("refcount_flag.py")
                if f.rule == "refcount-pair"]
    assert len(findings) >= 2


# ------------------------------------------------------------ suppressions

def test_suppressed_fixture_is_silent():
    assert _lint("suppressed.py") == []


def test_suppression_comment_parsing():
    lines = [
        "x = 1  # replint: ignore[guarded-by] -- reason",
        "# replint: ignore[stop-iteration, refcount-pair]",
        "y = 2",
        "plain = 3",
    ]
    sup = suppressed_lines(lines)
    assert sup[1] == {"guarded-by"}
    assert sup[3] == {"stop-iteration", "refcount-pair"}
    assert 4 not in sup


def test_suppression_only_silences_named_rule(tmp_path):
    p = tmp_path / "wrong_rule.py"
    p.write_text(
        "def f(gen):\n"
        "    raise StopIteration  # replint: ignore[guarded-by] -- wrong\n")
    findings = lint_file(str(p))
    assert [f.rule for f in findings] == ["stop-iteration"]


# --------------------------------------------------------------- baseline

def test_baseline_roundtrip(tmp_path):
    findings = _lint("stop_iteration_flag.py")
    assert findings
    base = tmp_path / "baseline.txt"
    write_baseline(str(base), findings)
    keys = load_baseline(str(base))
    assert keys == {f.baseline_key for f in findings}
    # every finding is grandfathered -> nothing "new"
    assert [f for f in findings if f.baseline_key not in keys] == []


def test_cli_baseline_gates_exit_code(tmp_path):
    flag = os.path.join(FIXTURES, "refcount_flag.py")
    base = tmp_path / "baseline.txt"
    env = {**os.environ, "PYTHONPATH": REPO}

    def run(*args):
        return subprocess.run(
            [sys.executable, "-m", "tools.replint", *args],
            capture_output=True, text=True, cwd=REPO, env=env)

    r = run(flag, "--no-baseline")
    assert r.returncode == 1, r.stdout + r.stderr
    assert "refcount-pair" in r.stdout

    r = run(flag, "--baseline", str(base), "--write-baseline")
    assert r.returncode == 0, r.stdout + r.stderr

    r = run(flag, "--baseline", str(base))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 new" in r.stdout


def test_cli_clean_file_exits_zero():
    clean = os.path.join(FIXTURES, "guarded_by_clean.py")
    r = subprocess.run(
        [sys.executable, "-m", "tools.replint", clean, "--no-baseline"],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "PYTHONPATH": REPO})
    assert r.returncode == 0, r.stdout + r.stderr


def test_parse_error_is_reported(tmp_path):
    p = tmp_path / "broken.py"
    p.write_text("def f(:\n")
    findings = lint_file(str(p))
    assert [f.rule for f in findings] == ["parse-error"]


# ------------------------------------------------------------- the gate

def test_repo_is_clean():
    """The committed tree must lint clean with an EMPTY baseline —
    the same gate scripts/lint.sh enforces in CI."""
    cwd = os.getcwd()
    os.chdir(REPO)
    try:
        findings, n_files = lint_paths(["src", "benchmarks"])
    finally:
        os.chdir(cwd)
    assert n_files > 50
    assert findings == [], "\n".join(f.render() for f in findings)
    committed = load_baseline(
        os.path.join(REPO, "tools", "replint", "baseline.txt"))
    assert committed == set(), "baseline must stay empty"
